"""repro: COX (CUDA-on-X86 via hierarchical collapsing) adapted to JAX/TPU,
embedded in a production-scale training/serving framework."""
__version__ = "0.1.0"
