"""Decoder-only LM assembly: dense / MoE / SSM / hybrid / VLM families.

Layers are scanned (stacked params) to keep HLO size and compile time
independent of depth — essential for the 512-device dry-runs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .params import ParamSpec, is_spec

# ---------------------------------------------------------------------------
# spec assembly
# ---------------------------------------------------------------------------


def _stack(spec_tree, n: int):
    def f(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, shape=(n,) + s.shape,
                                   axes=(None,) + tuple(s.axes or
                                                        (None,) * len(s.shape)))
    return jax.tree_util.tree_map(f, spec_tree, is_leaf=is_spec)


def _norm_pair(cfg, name: str) -> Dict[str, ParamSpec]:
    sp = {name: L.norm_spec(cfg)}
    if cfg.norm == "ln":
        sp[name + "_b"] = dataclasses.replace(L.norm_spec(cfg), init="zeros")
    return sp


def _dense_layer_specs(cfg) -> Dict[str, Any]:
    sp: Dict[str, Any] = {}
    sp.update(_norm_pair(cfg, "ln1"))
    sp["attn"] = L.attention_specs(cfg)
    sp.update(_norm_pair(cfg, "ln2"))
    if cfg.family == "moe":
        sp["moe"] = L.moe_specs(cfg)
    else:
        sp["mlp"] = L.mlp_specs(cfg)
    return sp


def _ssm_layer_specs(cfg) -> Dict[str, Any]:
    sp: Dict[str, Any] = {}
    sp.update(_norm_pair(cfg, "ln1"))
    sp["mamba"] = L.mamba2_specs(cfg)
    return sp


def lm_specs(cfg) -> Dict[str, Any]:
    specs: Dict[str, Any] = {"embed": L.embed_specs(cfg)}
    specs.update(_norm_pair(cfg, "final_norm"))
    if cfg.family in ("dense", "moe", "vlm"):
        specs["layers"] = _stack(_dense_layer_specs(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        specs["layers"] = _stack(_ssm_layer_specs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        specs["layers"] = _stack(_ssm_layer_specs(cfg), cfg.n_layers)
        shared = {}
        shared.update(_norm_pair(cfg, "ln1"))
        shared["attn"] = L.attention_specs(cfg)
        shared.update(_norm_pair(cfg, "ln2"))
        shared["mlp"] = L.mlp_specs(cfg)
        specs["shared_attn"] = shared
    else:
        raise ValueError(cfg.family)
    return specs


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _dense_layer_apply(cfg, rules, window, backend, lp, x, positions):
    h = L.apply_norm(lp["ln1"], x, cfg.norm, lp.get("ln1_b"), backend)
    h = L.attention_apply(lp["attn"], h, positions, cfg=cfg, rules=rules,
                          causal=True, window=window, backend=backend)
    x = x + h
    h = L.apply_norm(lp["ln2"], x, cfg.norm, lp.get("ln2_b"), backend)
    if cfg.family == "moe":
        h = L.moe_apply(lp["moe"], h, cfg=cfg, rules=rules)
    else:
        h = L.mlp_apply(lp["mlp"], h, cfg=cfg, rules=rules)
    return x + h


def _ssm_layer_apply(cfg, rules, backend, lp, x):
    h = L.apply_norm(lp["ln1"], x, cfg.norm, lp.get("ln1_b"), backend)
    h = L.mamba2_apply(lp["mamba"], h, cfg=cfg, rules=rules, backend=backend)
    return x + h


def _shared_attn_apply(cfg, rules, backend, sp, x, positions):
    h = L.apply_norm(sp["ln1"], x, cfg.norm, sp.get("ln1_b"), backend)
    h = L.attention_apply(sp["attn"], h, positions, cfg=cfg, rules=rules,
                          causal=True, window=cfg.window, backend=backend)
    x = x + h
    h = L.apply_norm(sp["ln2"], x, cfg.norm, sp.get("ln2_b"), backend)
    return x + L.mlp_apply(sp["mlp"], h, cfg=cfg, rules=rules)


def _scan_layers(layer_fn, stacked_params, x, remat: bool, rules=None):
    """Scan the layer stack.  The carry (residual stream) — which is what
    full remat saves per layer — is constrained to sequence-parallel
    sharding ('seq_act' → model) so 34B-class × 4k × 256-batch activation
    checkpoints fit per-device HBM; XLA inserts the all-gather /
    reduce-scatter pair around the head/mlp-sharded interior."""
    def seq_shard(h):
        return L.constrain(h, rules, ("batch", "seq_act", "embed"))

    fn = layer_fn
    if remat:
        fn = jax.checkpoint(fn)

    def body(carry, lp):
        return seq_shard(fn(lp, carry)), None

    x, _ = lax.scan(body, seq_shard(x), stacked_params)
    return x


def hidden_states(cfg, params, x, positions, *, rules=None, backend="auto"):
    """Run the layer stack on embedded inputs x: (B, S, d)."""
    remat = cfg.remat == "full"
    if cfg.family in ("dense", "moe", "vlm"):
        fn = functools.partial(_dense_layer_apply, cfg, rules, cfg.window,
                               backend)
        x = _scan_layers(lambda lp, h: fn(lp, h, positions),
                         params["layers"], x, remat, rules=rules)
    elif cfg.family == "ssm":
        fn = functools.partial(_ssm_layer_apply, cfg, rules, backend)
        x = _scan_layers(fn, params["layers"], x, remat, rules=rules)
    elif cfg.family == "hybrid":
        ae = cfg.attn_every or cfg.n_layers
        n = cfg.n_layers
        fn = functools.partial(_ssm_layer_apply, cfg, rules, backend)
        start = 0
        while start < n:
            width = min(ae, n - start)
            group = jax.tree_util.tree_map(
                lambda a: lax.slice_in_dim(a, start, start + width, axis=0),
                params["layers"])
            x = _scan_layers(fn, group, x, remat, rules=rules)
            x = _shared_attn_apply(cfg, rules, backend,
                                   params["shared_attn"], x, positions)
            start += width
    else:
        raise ValueError(cfg.family)
    return L.apply_norm(params["final_norm"], x, cfg.norm,
                        params.get("final_norm_b"), backend)


def forward(cfg, params, batch, *, rules=None, backend="auto"):
    """Training forward.  batch: tokens (B,S_text), labels (B,S_text),
    optional frontend (B,Nf,d) for vlm/audio.  Returns (loss, logits)."""
    tokens = batch["tokens"]
    x = L.embed_apply(params["embed"], tokens)
    if cfg.n_frontend_tokens:
        fe = batch["frontend"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = L.constrain(x, rules, ("batch", None, "embed"))
    h = hidden_states(cfg, params, x, positions, rules=rules, backend=backend)
    if cfg.n_frontend_tokens:
        h = h[:, cfg.n_frontend_tokens:]
    logits = L.unembed_apply(params["embed"], h, cfg)
    logits = L.constrain(logits, rules, ("batch", None, "vocab"))
    loss = L.cross_entropy(logits, batch["labels"], cfg.vocab)
    return loss, logits


# ---------------------------------------------------------------------------
# decode (serve step)
# ---------------------------------------------------------------------------


def cache_specs(cfg, batch: int, seq_len: int) -> Dict[str, Any]:
    """Abstract KV/state cache layout for one-token decode.

    Dense/MoE/VLM: per-layer KV (L, B, S, Hkv, Dh) — S sharded over
    'model' (seq_kv) so 32k×128 caches fit HBM.
    SSM: recurrent state (L, B, H, N, P) + conv tail.
    Hybrid: SSM states + one KV cache per shared-attention application
    (window-bounded for long contexts)."""
    Lc, B, S = cfg.n_layers, batch, seq_len
    Hkv, Dh = cfg.n_kv, cfg.d_head
    dt = cfg.param_dtype
    if cfg.family in ("dense", "moe", "vlm"):
        kv = ParamSpec((Lc, B, S, Hkv, Dh), dt,
                       (None, "batch", "seq_kv", "kv_heads", None),
                       init="zeros")
        return {"k": kv, "v": kv}
    di = cfg.ssm_inner
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_c = di + 2 * N
    ssm = {
        "h": ParamSpec((Lc, B, H, N, P), jnp.float32,
                       (None, "batch", "ssm_inner", None, None), init="zeros"),
        "conv": ParamSpec((Lc, B, cfg.conv_k - 1, conv_c), dt,
                          (None, "batch", None, "ssm_inner"), init="zeros"),
    }
    if cfg.family == "ssm":
        return ssm
    # hybrid: shared-attention KV per application, window-bounded
    ae = cfg.attn_every or cfg.n_layers
    n_app = -(-cfg.n_layers // ae)
    Sw = min(S, cfg.window) if cfg.window else S
    kv = ParamSpec((n_app, B, Sw, Hkv, Dh), dt,
                   (None, "batch", "seq_kv", "kv_heads", None), init="zeros")
    ssm.update({"k": kv, "v": kv})
    return ssm


def decode_step(cfg, params, cache, tokens, pos, *, rules=None,
                backend="auto"):
    """One token for every sequence.  tokens: (B,), pos: (B,) current
    lengths.  Returns (logits (B, Vpad), new cache)."""
    x = L.embed_apply(params["embed"], tokens)          # (B, d)
    x = L.constrain(x, rules, ("batch", "embed"))

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, inp):
            lp, kc, vc = inp
            hn = L.apply_norm(lp["ln1"], h, cfg.norm, lp.get("ln1_b"), backend)
            y, newkv = L.attention_decode(lp["attn"], hn, {"k": kc, "v": vc},
                                          pos, cfg=cfg, rules=rules,
                                          backend=backend)
            h = h + y
            hn = L.apply_norm(lp["ln2"], h, cfg.norm, lp.get("ln2_b"), backend)
            if cfg.family == "moe":
                y = L.moe_apply(lp["moe"], hn[:, None], cfg=cfg,
                                rules=rules)[:, 0]
            else:
                y = L.mlp_apply(lp["mlp"], hn[:, None], cfg=cfg,
                                rules=rules)[:, 0]
            return h + y, (newkv["k"], newkv["v"])

        h, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
        new_cache = {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        def body(h, inp):
            lp, hs, cs = inp
            hn = L.apply_norm(lp["ln1"], h, cfg.norm, lp.get("ln1_b"), backend)
            y, st = L.mamba2_decode(lp["mamba"], hn, {"h": hs, "conv": cs},
                                    cfg=cfg, backend=backend)
            return h + y, (st["h"], st["conv"])

        h, (hs, cs) = lax.scan(body, x, (params["layers"], cache["h"],
                                         cache["conv"]))
        new_cache = {"h": hs, "conv": cs}

    elif cfg.family == "hybrid":
        ae = cfg.attn_every or cfg.n_layers
        n = cfg.n_layers
        W = cache["k"].shape[2]
        slot = pos % W
        kv_len = jnp.minimum(pos + 1, W)
        h = x
        hs_out, cs_out, k_out, v_out = [], [], [], []
        start, app = 0, 0
        while start < n:
            width = min(ae, n - start)
            group = jax.tree_util.tree_map(
                lambda a: lax.slice_in_dim(a, start, start + width, axis=0),
                params["layers"])

            def body(hh, inp):
                lp, hstate, cstate = inp
                hn = L.apply_norm(lp["ln1"], hh, cfg.norm, lp.get("ln1_b"),
                                  backend)
                y, st = L.mamba2_decode(lp["mamba"], hn,
                                        {"h": hstate, "conv": cstate},
                                        cfg=cfg, backend=backend)
                return hh + y, (st["h"], st["conv"])

            h, (hs, cs) = lax.scan(
                body, h, (group,
                          lax.slice_in_dim(cache["h"], start,
                                           start + width, axis=0),
                          lax.slice_in_dim(cache["conv"], start,
                                           start + width, axis=0)))
            hs_out.append(hs)
            cs_out.append(cs)
            sp = params["shared_attn"]
            hn = L.apply_norm(sp["ln1"], h, cfg.norm, sp.get("ln1_b"), backend)
            y, newkv = L.attention_decode(
                sp["attn"], hn, {"k": cache["k"][app], "v": cache["v"][app]},
                pos, cfg=cfg, rules=rules, backend=backend,
                slot=slot, kv_len=kv_len)
            h = h + y
            hn = L.apply_norm(sp["ln2"], h, cfg.norm, sp.get("ln2_b"), backend)
            h = h + L.mlp_apply(sp["mlp"], hn[:, None], cfg=cfg,
                                rules=rules)[:, 0]
            k_out.append(newkv["k"])
            v_out.append(newkv["v"])
            start += width
            app += 1
        new_cache = {"h": jnp.concatenate(hs_out, 0),
                     "conv": jnp.concatenate(cs_out, 0),
                     "k": jnp.stack(k_out, 0), "v": jnp.stack(v_out, 0)}
    else:
        raise ValueError(cfg.family)

    h = L.apply_norm(params["final_norm"], h, cfg.norm,
                     params.get("final_norm_b"), backend)
    logits = L.unembed_apply(params["embed"], h, cfg)
    logits = L.constrain(logits, rules, ("batch", "vocab"))
    return logits, new_cache
