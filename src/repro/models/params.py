"""Lightweight functional parameter system with logical-axis sharding.

Every parameter is declared as a ``ParamSpec`` (shape, dtype, logical
axes).  Logical axes are resolved to mesh axes by ``AxisRules`` with a
divisible-or-replicate policy: if a dimension does not divide the mesh
axis extent, that dimension is replicated and the event is recorded (the
roofline report surfaces the cost; §Perf fixes the interesting ones,
e.g. head padding).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    axes: Tuple[Optional[str], ...] = ()   # logical axis names per dim
    init: str = "normal"                   # normal | zeros | ones
    scale: float = 1.0

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} vs shape {self.shape}")

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_abstract(spec_tree):
    return jax.tree_util.tree_map(
        lambda s: s.abstract(), spec_tree, is_leaf=is_spec)


def init_params(spec_tree, key) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, spec.dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, spec.dtype))
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, spec.shape, jnp.float32)
                        * std).astype(spec.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AxisRules:
    """logical axis -> tuple of mesh axes (in priority order)."""
    rules: Dict[str, Tuple[str, ...]]
    mesh: Mesh
    notes: List[str] = dataclasses.field(default_factory=list)

    def mesh_size(self, names: Tuple[str, ...]) -> int:
        n = 1
        for m in names:
            n *= self.mesh.shape[m]
        return n

    def partition_spec(self, spec: ParamSpec) -> P:
        return self.pspec_for(spec.shape, spec.axes, what=str(spec.shape))

    def pspec_for(self, shape, axes, what: str = "") -> P:
        entries: List[Any] = []
        used: set = set()
        for dim, ax in zip(shape, axes or (None,) * len(shape)):
            if ax is None or ax not in self.rules:
                entries.append(None)
                continue
            names = tuple(m for m in self.rules[ax] if m not in used
                          and m in self.mesh.shape)
            if not names:
                entries.append(None)
                continue
            if dim % self.mesh_size(names) != 0:
                # divisible-or-replicate fallback: try prefixes
                ok = None
                for cut in range(len(names) - 1, 0, -1):
                    if dim % self.mesh_size(names[:cut]) == 0:
                        ok = names[:cut]
                        break
                if ok is None:
                    self.notes.append(
                        f"replicated {ax}={dim} of {what}: not divisible by "
                        f"mesh{names}={self.mesh_size(names)}")
                    entries.append(None)
                    continue
                names = ok
            used.update(names)
            entries.append(names if len(names) > 1 else names[0])
        return P(*entries)

    def sharding(self, spec: ParamSpec) -> NamedSharding:
        return NamedSharding(self.mesh, self.partition_spec(spec))

    def tree_pspecs(self, spec_tree):
        return jax.tree_util.tree_map(self.partition_spec, spec_tree,
                                      is_leaf=is_spec)

    def tree_shardings(self, spec_tree):
        return jax.tree_util.tree_map(self.sharding, spec_tree,
                                      is_leaf=is_spec)


def default_rules(mesh: Mesh, strategy: str = "tp") -> AxisRules:
    """The framework's logical-axis tables (DESIGN.md §5).

    strategy="tp"   — Megatron-style: batch→data, heads/mlp/experts→model,
                      sequence-parallel residuals. (paper-era default)
    strategy="fsdp" — fully-sharded data parallel: batch over EVERY mesh
                      axis (1 sequence/chip at the assigned shapes) and
                      weights sharded over (data×model) on their embed
                      dim; XLA inserts per-layer weight all-gathers and
                      gradient reduce-scatters.  Wins when per-device
                      token counts make TP activation all-gathers dwarf
                      weight traffic (the §Perf granite/yi finding).
    """
    has_pod = "pod" in mesh.shape
    if strategy == "fsdp":
        everything = (("pod", "data", "model") if has_pod
                      else ("data", "model"))
        return AxisRules(rules={
            "batch": everything,
            "vocab": everything,   # embedding table fully sharded
            "heads": (),
            "kv_heads": (),
            "kv_embed": everything,
            "mlp": (),
            "experts": ("model",),
            "ssm_inner": (),
            "seq_kv": ("model",),
            "seq_act": (),
            "embed": everything,   # weight embed dims fully sharded
            "opt_data": (),
        }, mesh=mesh)
    batch = ("pod", "data") if has_pod else ("data",)
    return AxisRules(rules={
        "batch": batch,
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "kv_embed": ("model",),   # row-parallel kv projections (TP > Hkv)
        "mlp": ("model",),
        "experts": ("model",),
        "ssm_inner": ("model",),
        "seq_kv": ("model",),     # decode KV caches shard on sequence
        "seq_act": ("model",),    # Megatron-style sequence parallelism for
                                  # layer-boundary residuals (remat saves)
        "embed": (),              # d_model replicated (activations row dim)
        "opt_data": ("data",),    # ZeRO-1 optimizer-state extra axis
    }, mesh=mesh)


def zero1_pspec(rules: AxisRules, spec: ParamSpec) -> P:
    """Optimizer-state sharding: the param's own spec, plus 'data' on the
    first still-unsharded divisible dimension (ZeRO-1)."""
    base = rules.partition_spec(spec)
    entries = list(base)
    used = set()
    for e in entries:
        if e is None:
            continue
        used.update((e,) if isinstance(e, str) else e)
    dsize = rules.mesh.shape.get("data", 1)
    if dsize == 1 or "data" in used:
        return base
    for i, (dim, cur) in enumerate(zip(spec.shape, entries)):
        if cur is None and dim % dsize == 0:
            entries[i] = "data"
            return P(*entries)
    return base
