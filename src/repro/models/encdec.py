"""Encoder-decoder model (seamless-m4t backbone).

The speech/text modality frontend is a STUB per the brief: the encoder
consumes precomputed frame embeddings (B, S_enc, d) supplied by
``input_specs``.  The decoder is a standard causal transformer with
cross-attention; decode shapes lower the *decoder* serve step with the
encoder memory precomputed.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .lm import _norm_pair, _stack
from .params import ParamSpec


def cross_attention_specs(cfg) -> Dict[str, ParamSpec]:
    return L.attention_specs(cfg)


def encdec_specs(cfg) -> Dict[str, Any]:
    enc_layer: Dict[str, Any] = {}
    enc_layer.update(_norm_pair(cfg, "ln1"))
    enc_layer["attn"] = L.attention_specs(cfg)
    enc_layer.update(_norm_pair(cfg, "ln2"))
    enc_layer["mlp"] = L.mlp_specs(cfg)

    dec_layer: Dict[str, Any] = {}
    dec_layer.update(_norm_pair(cfg, "ln1"))
    dec_layer["attn"] = L.attention_specs(cfg)
    dec_layer.update(_norm_pair(cfg, "lnx"))
    dec_layer["xattn"] = cross_attention_specs(cfg)
    dec_layer.update(_norm_pair(cfg, "ln2"))
    dec_layer["mlp"] = L.mlp_specs(cfg)

    specs: Dict[str, Any] = {
        "embed": L.embed_specs(cfg),
        "enc_layers": _stack(enc_layer, cfg.enc_layers),
        "dec_layers": _stack(dec_layer, cfg.n_layers),
    }
    specs.update(_norm_pair(cfg, "enc_norm"))
    specs.update(_norm_pair(cfg, "final_norm"))
    return specs


def _cross_attend(p, x, mem_k, mem_v, *, cfg, rules, backend):
    """x: (B,S,d) queries; mem_k/v: (B,Se,Hkv,Dh) precomputed."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    att = jax.vmap(lambda qq, kk, vv: ops_attention(
        qq, kk, vv, backend))(q, mem_k, mem_v)
    out = jnp.einsum("bshk,hkd->bsd", att, p["wo"])
    return L.constrain(out, rules, ("batch", None, "embed"))


def ops_attention(q, k, v, backend):
    from ..kernels import ops
    return ops.attention(q, k, v, causal=False, backend=backend)


def encode(cfg, params, frames, *, rules=None, backend="auto"):
    """frames: (B, Se, d) precomputed frontend embeddings."""
    B, Se, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    x = frames

    def layer(lp, h):
        hn = L.apply_norm(lp["ln1"], h, cfg.norm, lp.get("ln1_b"), backend)
        hn = L.attention_apply(lp["attn"], hn, positions, cfg=cfg,
                               rules=rules, causal=False, backend=backend)
        h = h + hn
        hn = L.apply_norm(lp["ln2"], h, cfg.norm, lp.get("ln2_b"), backend)
        return h + L.mlp_apply(lp["mlp"], hn, cfg=cfg, rules=rules)

    fn = jax.checkpoint(layer) if cfg.remat == "full" else layer

    def body(carry, lp):
        return fn(lp, carry), None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(params["enc_norm"], x, cfg.norm,
                        params.get("enc_norm_b"), backend)


def _mem_kv(p, mem):
    k = jnp.einsum("bsd,dhk->bshk", mem, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mem, p["wv"])
    return k, v


def forward(cfg, params, batch, *, rules=None, backend="auto"):
    """batch: frontend (B,Se,d), tokens (B,S), labels (B,S)."""
    mem = encode(cfg, params, batch["frontend"].astype(cfg.param_dtype),
                 rules=rules, backend=backend)
    tokens = batch["tokens"]
    x = L.embed_apply(params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def layer(lp, h):
        hn = L.apply_norm(lp["ln1"], h, cfg.norm, lp.get("ln1_b"), backend)
        hn = L.attention_apply(lp["attn"], hn, positions, cfg=cfg,
                               rules=rules, causal=True, backend=backend)
        h = h + hn
        hn = L.apply_norm(lp["lnx"], h, cfg.norm, lp.get("lnx_b"), backend)
        mk, mv = _mem_kv(lp["xattn"], mem)
        h = h + _cross_attend(lp["xattn"], hn, mk, mv, cfg=cfg, rules=rules,
                              backend=backend)
        hn = L.apply_norm(lp["ln2"], h, cfg.norm, lp.get("ln2_b"), backend)
        return h + L.mlp_apply(lp["mlp"], hn, cfg=cfg, rules=rules)

    fn = jax.checkpoint(layer) if cfg.remat == "full" else layer

    def body(carry, lp):
        return fn(lp, carry), None

    x, _ = lax.scan(body, x, params["dec_layers"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm,
                     params.get("final_norm_b"), backend)
    logits = L.unembed_apply(params["embed"], x, cfg)
    loss = L.cross_entropy(logits, batch["labels"], cfg.vocab)
    return loss, logits


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def cache_specs(cfg, batch: int, seq_len: int, enc_len: int) -> Dict[str, Any]:
    Lc, B = cfg.n_layers, batch
    Hkv, Dh = cfg.n_kv, cfg.d_head
    dt = cfg.param_dtype
    kv = ParamSpec((Lc, B, seq_len, Hkv, Dh), dt,
                   (None, "batch", "seq_kv", "kv_heads", None), init="zeros")
    xkv = ParamSpec((Lc, B, enc_len, Hkv, Dh), dt,
                    (None, "batch", "seq_kv", "kv_heads", None), init="zeros")
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv}


def decode_step(cfg, params, cache, tokens, pos, *, rules=None,
                backend="auto"):
    """Decoder-only serve step with precomputed cross K/V in the cache."""
    from ..kernels import ops
    x = L.embed_apply(params["embed"], tokens)

    def body(h, inp):
        lp, kc, vc, xk, xv = inp
        hn = L.apply_norm(lp["ln1"], h, cfg.norm, lp.get("ln1_b"), backend)
        y, newkv = L.attention_decode(lp["attn"], hn, {"k": kc, "v": vc},
                                      pos, cfg=cfg, rules=rules,
                                      backend=backend)
        h = h + y
        hn = L.apply_norm(lp["lnx"], h, cfg.norm, lp.get("lnx_b"), backend)
        q = jnp.einsum("bd,dhk->bhk", hn, lp["xattn"]["wq"])
        enc_len = xk.shape[1]
        att = jax.vmap(lambda qq, kk, vv: ops.decode_attention(
            qq, kk, vv, enc_len, backend=backend))(q, xk, xv)
        h = h + jnp.einsum("bhk,hkd->bd", att, lp["xattn"]["wo"])
        hn = L.apply_norm(lp["ln2"], h, cfg.norm, lp.get("ln2_b"), backend)
        h = h + L.mlp_apply(lp["mlp"], hn[:, None], cfg=cfg, rules=rules)[:, 0]
        return h, (newkv["k"], newkv["v"])

    h, (ks, vs) = lax.scan(body, x, (params["dec_layers"], cache["k"],
                                     cache["v"], cache["xk"], cache["xv"]))
    new_cache = dict(cache, k=ks, v=vs)
    h = L.apply_norm(params["final_norm"], h, cfg.norm,
                     params.get("final_norm_b"), backend)
    logits = L.unembed_apply(params["embed"], h, cfg)
    return logits, new_cache
