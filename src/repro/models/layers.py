"""Model building blocks (functional: spec builders + apply functions).

All heavy math calls the kernel dispatch layer (repro.kernels.ops), so
the same model runs Pallas kernels on TPU and compact XLA math on the
CPU dry-run.  Activation shardings are expressed with
``with_sharding_constraint`` through the AxisRules table.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ops
from .params import AxisRules, ParamSpec

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def constrain(x, rules: Optional[AxisRules], axes):
    if rules is None:
        return x
    return lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(
            rules.mesh, rules.pspec_for(x.shape, axes, what="act")))


def round_up(a: int, b: int) -> int:
    return -(-a // b) * b


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, *, base: float = 10000.0):
    """x: (..., S, H, D) or (..., H, D) with positions broadcastable."""
    D = x.shape[-1]
    half = D // 2
    freqs = jnp.exp(-jnp.log(base) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_spec(cfg) -> ParamSpec:
    return ParamSpec((cfg.d_model,), jnp.float32, ("embed",), init="ones")


def apply_norm(w, x, kind: str = "rms", b=None, backend: str = "auto"):
    if kind == "rms":
        return ops.rmsnorm(x, w, backend=backend)
    return ops.layernorm(x, w, b if b is not None else jnp.zeros_like(w),
                         backend=backend)


# ---------------------------------------------------------------------------
# attention (GQA, rope, optional window) — train/prefill and decode paths
# ---------------------------------------------------------------------------


def attention_specs(cfg, d_model: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = d_model or cfg.d_model
    Hp, gp, g = cfg.head_padding()
    Hkv, Dh = cfg.n_kv, cfg.d_head
    dt = cfg.param_dtype
    # KV projections: column-parallel over kv heads when divisible by TP,
    # else row-parallel over d_model ("kv_embed" → model): the weights
    # stay sharded and XLA inserts a small all-reduce on the kv
    # activations instead of replicating the parameters.
    kv_col = (not cfg.tp_pad) or (Hkv % cfg.tp_pad == 0)
    kv_axes = ("embed", "kv_heads", None) if kv_col \
        else ("kv_embed", None, None)
    sp = {
        "wq": ParamSpec((d, Hp, Dh), dt, ("embed", "heads", None)),
        "wk": ParamSpec((d, Hkv, Dh), dt, kv_axes),
        "wv": ParamSpec((d, Hkv, Dh), dt, kv_axes),
        "wo": ParamSpec((Hp, Dh, d), dt, ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        sp["bq"] = ParamSpec((Hp, Dh), dt, ("heads", None), init="zeros")
        sp["bk"] = ParamSpec((Hkv, Dh), dt, ("kv_heads", None), init="zeros")
        sp["bv"] = ParamSpec((Hkv, Dh), dt, ("kv_heads", None), init="zeros")
    return sp


def _head_mask(cfg):
    """(Hp,) validity mask: padded q-head slots contribute zero to the
    output projection, making padded execution exactly equal to the
    true architecture."""
    Hp, gp, g = cfg.head_padding()
    if Hp == cfg.n_heads:
        return None
    slot = jnp.arange(Hp) % gp
    return (slot < g)


def attention_apply(p, x, positions, *, cfg, rules=None, causal=True,
                    window: int = 0, backend="auto"):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = rope(q, positions)
    k = rope(k, positions)
    q = constrain(q, rules, ("batch", None, "heads", None))
    k = constrain(k, rules, ("batch", None, "kv_heads", None))
    att = jax.vmap(lambda qq, kk, vv: ops.attention(
        qq, kk, vv, causal=causal, window=window, backend=backend))(q, k, v)
    att = constrain(att, rules, ("batch", None, "heads", None))
    hm = _head_mask(cfg)
    if hm is not None:
        att = att * hm[None, None, :, None].astype(att.dtype)
    out = jnp.einsum("bshk,hkd->bsd", att, p["wo"])
    return constrain(out, rules, ("batch", None, "embed"))


def attention_decode(p, x, cache, pos, *, cfg, rules=None, backend="auto",
                     slot=None, kv_len=None):
    """One-token decode.  x: (B, d); cache: {k: (B, S, Hkv, Dh), v: ...};
    pos: (B,) absolute positions (for RoPE); slot: (B,) cache write slots
    (rolling-buffer windows; defaults to pos); kv_len: (B,) valid cache
    length (defaults to pos+1)."""
    B, d = x.shape
    slot = pos if slot is None else slot
    kv_len = pos + 1 if kv_len is None else kv_len
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # rope wants (..., S, H, D): add a singleton S axis
    qr = rope(q[:, None], pos[:, None])[:, 0]
    kr = rope(k[:, None], pos[:, None])[:, 0]
    kc = _scatter_token(cache["k"], kr, slot)
    vc = _scatter_token(cache["v"], v, slot)
    out = jax.vmap(lambda qq, kk, vv, ln: ops.decode_attention(
        qq, kk, vv, ln, backend=backend))(qr, kc, vc, kv_len)
    hm = _head_mask(cfg)
    if hm is not None:
        out = out * hm[None, :, None].astype(out.dtype)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])
    return y, {"k": kc, "v": vc}


def _scatter_token(cache, token, pos):
    """cache: (B, S, H, D); token: (B, H, D); pos: (B,)."""
    def one(c, t, i):
        return lax.dynamic_update_slice_in_dim(
            c, t[None].astype(c.dtype), i, axis=0)
    return jax.vmap(one)(cache, token, pos)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.param_dtype
    if cfg.act == "swiglu":
        return {"w_gate": ParamSpec((d, f), dt, ("embed", "mlp")),
                "w_up": ParamSpec((d, f), dt, ("embed", "mlp")),
                "w_down": ParamSpec((f, d), dt, ("mlp", "embed"))}
    return {"w_in": ParamSpec((d, f), dt, ("embed", "mlp")),
            "w_out": ParamSpec((f, d), dt, ("mlp", "embed"))}


def mlp_apply(p, x, *, cfg, rules=None):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = constrain(h, rules, ("batch", None, "mlp"))
        out = h @ p["w_down"]
    else:
        h = jax.nn.gelu(x @ p["w_in"])
        h = constrain(h, rules, ("batch", None, "mlp"))
        out = h @ p["w_out"]
    return constrain(out, rules, ("batch", None, "embed"))


# ---------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k, einsum dispatch; EP over 'experts')
# ---------------------------------------------------------------------------


def moe_specs(cfg) -> Dict[str, ParamSpec]:
    d, fe = cfg.d_model, cfg.d_expert or cfg.d_ff
    E = cfg.n_experts
    dt = cfg.param_dtype
    sp = {
        "router": ParamSpec((d, E), jnp.float32, ("embed", None)),
        "w_gate": ParamSpec((E, d, fe), dt, ("experts", "embed", None)),
        "w_up": ParamSpec((E, d, fe), dt, ("experts", "embed", None)),
        "w_down": ParamSpec((E, fe, d), dt, ("experts", None, "embed")),
    }
    if cfg.n_shared:
        fs = fe * cfg.n_shared
        sp.update({
            "s_gate": ParamSpec((d, fs), dt, ("embed", "mlp")),
            "s_up": ParamSpec((d, fs), dt, ("embed", "mlp")),
            "s_down": ParamSpec((fs, d), dt, ("mlp", "embed"))})
    return sp


def moe_capacity(cfg, n_tokens: int) -> int:
    c = int(-(-cfg.top_k * n_tokens * cfg.capacity_factor // cfg.n_experts))
    return max(8, -(-c // 8) * 8)


def _moe_local(p, xt, *, cfg, C: int, e_lo, E_loc: int):
    """Token-choice top-k over a LOCAL token slab, computing only the
    expert slice [e_lo, e_lo+E_loc) whose weights this rank holds.
    Returns the (partial) combined output — summed over ranks outside.
    GShard positions: per-choice running cumsum, no sort."""
    T, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = xt.astype(jnp.float32) @ p["router"]            # (T,E)
    w, idx = ops.topk_gate(logits, k)                        # (T,k)

    base_count = jnp.zeros((E,), jnp.int32)
    slots, keeps = [], []
    for j in range(k):                                       # k small: unroll
        mask_j = jax.nn.one_hot(idx[:, j], E, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(mask_j, axis=0) - mask_j
        pos_j = (pos_in_e * mask_j).sum(-1) + base_count[idx[:, j]]
        base_count = base_count + mask_j.sum(0)
        keep_j = pos_j < C
        # slot relative to this rank's expert slice; OOB -> trash row
        rel_e = idx[:, j] - e_lo
        mine = keep_j & (rel_e >= 0) & (rel_e < E_loc)
        slots.append(jnp.where(mine, rel_e * C + pos_j, E_loc * C))
        keeps.append(mine)

    xe = jnp.zeros((E_loc * C + 1, d), xt.dtype)
    for j in range(k):
        xe = xe.at[slots[j]].set(xt, mode="drop")
    xe = xe[: E_loc * C].reshape(E_loc, C, d)

    h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                    p["w_down"]).astype(jnp.float32)
    ye_flat = jnp.concatenate(
        [ye.reshape(E_loc * C, d), jnp.zeros((1, d), jnp.float32)], 0)

    y = jnp.zeros((T, d), jnp.float32)
    for j in range(k):
        contrib = ye_flat[slots[j]] * (w[:, j] * keeps[j])[:, None]
        y = y + contrib
    return y


def moe_apply(p, x, *, cfg, rules=None):
    """Capacity-based token-choice top-k MoE with Megatron-style expert
    parallelism: tokens stay sharded on the data axis (activations are
    replicated over 'model'), each model rank runs only its expert slice
    on its data slab, and one psum over 'model' combines partial outputs.
    No global scatter, no token all-to-all.  Tokens beyond per-slab
    expert capacity are dropped (capacity_factor controls head-room;
    smoke configs use a no-drop factor, tested against the dense-dispatch
    oracle)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    if rules is None or "model" not in rules.mesh.shape:
        # single-device / test path: all experts local
        xt = x.reshape(B * S, d)
        C = moe_capacity(cfg, B * S)
        y = _moe_local(p, xt, cfg=cfg, C=C, e_lo=jnp.int32(0), E_loc=E)
        out = y.astype(x.dtype)
        if cfg.n_shared:
            out = out + (jax.nn.silu(xt @ p["s_gate"]) *
                         (xt @ p["s_up"])) @ p["s_down"]
        return constrain(out.reshape(B, S, d), rules,
                         ("batch", None, "embed"))

    mesh = rules.mesh
    tp = mesh.shape["model"]
    assert E % tp == 0, "experts must divide the model axis"
    E_loc = E // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    b_spec = batch_axes if B % dp == 0 else None
    from jax.sharding import PartitionSpec as P
    x_spec = P(b_spec, None, None)
    router_spec = P(None, None)
    ew_spec = P("model", None, None)

    def local(xb, router, wg, wu, wd):
        Bl, Sl, _ = xb.shape
        xt = xb.reshape(Bl * Sl, d)
        C = moe_capacity(cfg, Bl * Sl)
        rank = jax.lax.axis_index("model")
        pl = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        y = _moe_local(pl, xt, cfg=cfg, C=C, e_lo=rank * E_loc, E_loc=E_loc)
        y = jax.lax.psum(y, "model")
        return y.reshape(Bl, Sl, d).astype(xb.dtype)

    from repro.core.compat import shard_map
    y = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, router_spec, ew_spec, ew_spec, ew_spec),
        out_specs=x_spec, check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    out = y
    if cfg.n_shared:
        xt = x.reshape(B * S, d)
        sh = (jax.nn.silu(xt @ p["s_gate"]) * (xt @ p["s_up"])) @ p["s_down"]
        out = out + sh.reshape(B, S, d)
    return constrain(out, rules, ("batch", None, "embed"))


def moe_apply_dense(p, x, *, cfg, rules=None):
    """Dense-dispatch oracle (exact, no capacity): used by tests to
    validate the capacity path on small shapes."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = (x.astype(jnp.float32) @ p["router"])
    w, idx = ops.topk_gate(logits.reshape(-1, E), k)
    T = B * S
    xt = x.reshape(T, d)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)
    combine = (w[..., None] * onehot).sum(1)                 # (T,E)
    h = jnp.einsum("td,edf->tef", xt, p["w_gate"])
    u = jnp.einsum("td,edf->tef", xt, p["w_up"])
    h = jax.nn.silu(h) * u
    yv = jnp.einsum("tef,efd->ted", h, p["w_down"]).astype(jnp.float32)
    out = jnp.einsum("ted,te->td", yv, combine).astype(x.dtype)
    if cfg.n_shared:
        out = out + (jax.nn.silu(xt @ p["s_gate"]) * (xt @ p["s_up"])) @ p["s_down"]
    return constrain(out.reshape(B, S, d), rules, ("batch", None, "embed"))


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------


def mamba2_specs(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di = cfg.ssm_inner            # 2*d typically
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dt = cfg.param_dtype
    return {
        # in_proj → [z (gate), x, B, C, dt]
        "w_in": ParamSpec((d, 2 * di + 2 * N + H), dt,
                          ("embed", "ssm_inner")),
        "conv": ParamSpec((cfg.conv_k, di + 2 * N), dt, (None, "ssm_inner")),
        "A_log": ParamSpec((H,), jnp.float32, (None,), init="zeros"),
        "D": ParamSpec((H,), jnp.float32, (None,), init="ones"),
        "dt_bias": ParamSpec((H,), jnp.float32, (None,), init="zeros"),
        "norm": ParamSpec((di,), jnp.float32, ("ssm_inner",), init="ones"),
        "w_out": ParamSpec((di, d), dt, ("ssm_inner", "embed")),
    }


def _mamba_split(cfg, proj):
    di, N, H = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * N]
    dt = proj[..., di + di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv, state=None):
    """Depthwise causal conv along S. xBC: (B,S,C); conv: (K,C).
    If state (B,K-1,C) given, runs in streaming mode, returns new state."""
    K = conv.shape[0]
    if state is None:
        pad = jnp.zeros_like(xBC[:, :K - 1])
        xp = jnp.concatenate([pad, xBC], axis=1)
    else:
        xp = jnp.concatenate([state.astype(xBC.dtype), xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * conv[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(out), new_state


def mamba2_apply(p, x, *, cfg, rules=None, backend="auto"):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di, N, H, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["w_in"]
    z, xBC, dtp = _mamba_split(cfg, proj)
    xBC, _ = _causal_conv(xBC, p["conv"])
    xs = xBC[..., :di]
    Bm = xBC[..., di:di + N].astype(jnp.float32)
    Cm = xBC[..., di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    a = (A * dt)                                                      # (B,S,H) ≤0
    xh = (xs.reshape(B, S, H, P).astype(jnp.float32)
          * dt[..., None])                                            # dt-scaled
    chunk = min(cfg.ssd_chunk, S)
    y = jax.vmap(lambda xx, aa, bb, cc: ops.ssd_scan(
        xx, aa, bb, cc, chunk=chunk, backend=backend))(xh, a, Bm, Cm)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = ops.rmsnorm(y, p["norm"], backend=backend)
    out = y @ p["w_out"]
    return constrain(out, rules, ("batch", None, "embed"))


def mamba2_decode(p, x, state, *, cfg, backend="auto"):
    """One-token recurrent step.  x: (B, d);
    state: {"h": (B,H,N,P) f32, "conv": (B,K-1,C)}."""
    B, d = x.shape
    di, N, H, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["w_in"]
    z, xBC, dtp = _mamba_split(cfg, proj[:, None])
    xBC, conv_state = _causal_conv(xBC, p["conv"], state["conv"])
    z, xBC, dtp = z[:, 0], xBC[:, 0], dtp[:, 0]
    xs = xBC[..., :di]
    Bm = xBC[..., di:di + N].astype(jnp.float32)
    Cm = xBC[..., di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(A * dt)                                       # (B,H)
    xh = xs.reshape(B, H, P).astype(jnp.float32) * dt[..., None]
    h = state["h"] * decay[..., None, None] + \
        jnp.einsum("bn,bhp->bhnp", Bm, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cm, h) + xh * p["D"][None, :, None]
    y = y.reshape(B, di).astype(x.dtype) * jax.nn.silu(z)
    y = ops.rmsnorm(y, p["norm"], backend=backend)
    return y @ p["w_out"], {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg) -> Dict[str, ParamSpec]:
    vpad = round_up(cfg.vocab, 256)
    sp = {"tok": ParamSpec((vpad, cfg.d_model), cfg.param_dtype,
                           ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        sp["unembed"] = ParamSpec((cfg.d_model, vpad), cfg.param_dtype,
                                  ("embed", "vocab"))
    return sp


def embed_apply(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed_apply(p, x, cfg):
    w = p.get("unembed")
    if w is None:
        w = p["tok"].T
    return (x @ w).astype(jnp.float32)


def cross_entropy(logits, labels, vocab: int):
    """logits: (B,S,Vpad) f32; labels: (B,S) int32; mean over valid."""
    vpad = logits.shape[-1]
    mask = jnp.arange(vpad) < vocab
    logits = jnp.where(mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    valid = (labels >= 0) & (labels < vocab)
    nll = jnp.where(valid, lse - ll, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)
