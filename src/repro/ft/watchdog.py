"""Fault-tolerance machinery.

* ``StepWatchdog`` — per-step deadline detection (straggler/hang): if a
  step exceeds ``deadline_s``, the registered callback fires (on a real
  cluster: re-dispatch the step's grid chunk / evict the slow host; here:
  record + raise after ``max_strikes``).
* ``FailureInjector`` — deterministic fault injection for tests and
  drills (fail at step N with an exception, or corrupt a device buffer).
* ``retry_loop`` — run a step function with restart-from-checkpoint
  semantics: on failure, reload the latest checkpoint and continue; the
  deterministic data pipeline guarantees no sample is skipped/replayed.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional


class StepWatchdog:
    """Arm with :meth:`start` before a step, disarm with :meth:`stop`
    after it; a step that outlives ``deadline_s`` is a *strike* (the
    timer fires, the event is recorded, ``on_straggler`` runs).  A
    generation counter makes the lifecycle safe against the three
    classic timer races: ``start()`` while armed cancels the leaked
    prior timer, a healthy ``stop()`` resets the strike count (only
    *consecutive* stragglers accumulate toward ``max_strikes``), and a
    ``_fire`` racing a concurrent ``stop()`` observes a stale
    generation and does nothing (no fire-after-cancel)."""

    def __init__(self, deadline_s: float, on_straggler: Optional[Callable] = None,
                 max_strikes: int = 3):
        self.deadline_s = deadline_s
        self.on_straggler = on_straggler
        self.max_strikes = max_strikes
        self.strikes = 0
        self.events: list = []
        self._timer: Optional[threading.Timer] = None
        self._step = -1
        self._lock = threading.Lock()
        self._gen = 0        # bumped by every start()/stop()
        self._fired_gen = -1  # generation whose timer fired

    def start(self, step: int):
        with self._lock:
            if self._timer is not None:
                self._timer.cancel()     # re-arm: drop the leaked timer
            self._gen += 1
            self._step = step
            timer = threading.Timer(self.deadline_s, self._fire,
                                    args=(self._gen,))
            timer.daemon = True
            self._timer = timer
        timer.start()

    def _fire(self, gen: int):
        with self._lock:
            if gen != self._gen:         # lost the race to stop()/start()
                return
            self._fired_gen = gen
            self.strikes += 1
            self.events.append({"step": self._step, "time": time.time(),
                                "strikes": self.strikes})
            cb, step, strikes = self.on_straggler, self._step, self.strikes
        if cb:                           # callback outside the lock
            cb(step, strikes)

    @property
    def fired(self) -> bool:
        """True once the *currently armed* step's deadline expired."""
        with self._lock:
            return self._fired_gen == self._gen

    def stop(self):
        with self._lock:
            if self._timer is not None:
                self._timer.cancel()
                self._timer = None
            healthy = self._fired_gen != self._gen
            self._gen += 1               # invalidate any in-flight _fire
            if healthy:
                self.strikes = 0         # a healthy step clears the count

    def check(self):
        if self.strikes >= self.max_strikes:
            raise TimeoutError(
                f"{self.strikes} straggler strikes (deadline "
                f"{self.deadline_s}s) — evicting this worker for restart")


class FailureInjector:
    """Deterministic failures for drills: fail_at={step: exception}."""

    def __init__(self, fail_at: Optional[Dict[int, Exception]] = None):
        self.fail_at = dict(fail_at or {})
        self.fired: set = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.fail_at[step]


def retry_loop(run_from: Callable[[int], int], *, ckpt_mgr,
               max_restarts: int = 3) -> int:
    """``run_from(start_step) -> final_step`` with restart-on-failure.
    Each restart resumes from the latest durable checkpoint."""
    restarts = 0
    start = (ckpt_mgr.latest_step() or -1) + 1
    while True:
        try:
            return run_from(start)
        except (RuntimeError, TimeoutError, ValueError) as e:  # worker fault
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt_mgr.wait()
            latest = ckpt_mgr.latest_step()
            start = (latest or -1) + 1 if latest is not None else 0
            print(f"[ft] restart {restarts}/{max_restarts} after "
                  f"{type(e).__name__}: resuming from step {start}",
                  flush=True)
