"""AdamW with warmup-cosine schedule, global-norm clipping, and optional
int8 gradient compression with error feedback (the cross-pod wire-format
trick; numerics simulated exactly, wire savings counted in §Perf)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0
    grad_compress: bool = False  # int8 + error feedback


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    def zeros32(p):
        return jnp.zeros(p.shape, jnp.float32)
    st = {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.grad_compress:
        st["err"] = jax.tree_util.tree_map(zeros32, params)
    return st


def _quantize_int8(g):
    """Symmetric per-tensor int8 round-trip (the wire format)."""
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q * scale


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

    if cfg.grad_compress:
        # error feedback: transmit quant(g + e); keep the residual
        sent = jax.tree_util.tree_map(
            lambda g, e: _quantize_int8(g + e), g32, state["err"])
        new_err = jax.tree_util.tree_map(
            lambda g, e, s: g + e - s, g32, state["err"], sent)
        g32 = sent
    else:
        new_err = state.get("err")

    gnorm = _global_norm(g32)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm else 1.0
    g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    m = jax.tree_util.tree_map(lambda mm, g: cfg.b1 * mm + (1 - cfg.b1) * g,
                               state["m"], g32)
    v = jax.tree_util.tree_map(lambda vv, g: cfg.b2 * vv + (1 - cfg.b2) * g * g,
                               state["v"], g32)
    lr = schedule(cfg, step)

    def upd(p, mm, vv):
        mhat = mm / b1c
        vhat = vv / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    new_state = {"m": m, "v": v, "step": step}
    if cfg.grad_compress:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
