"""Sharded checkpointing with atomic commit, async save, elastic restore.

Layout (one directory per step):

    <dir>/step_000100.tmp/...      while writing
    <dir>/step_000100/manifest.json
    <dir>/step_000100/<leaf-path>.npy
    <dir>/LATEST                   atomic pointer file

Design points for the 1000-node posture:
* arrays are written in *logical* (unsharded) layout — a restore may use
  any mesh/sharding (elastic scaling: N pods → M pods just works);
* commit is atomic: write to `.tmp`, fsync, rename, then swap LATEST —
  a crash mid-save never corrupts the restore point;
* saves run on a background thread (training continues; `wait()` joins);
* every leaf records dtype/shape in the manifest and is verified on
  load (detects silent corruption / topology mismatch).

On a real cluster the npy writes go per-host for the host's shards
(process-local paths); on this single-host validation platform the full
array is written once.  bf16 is stored via a uint16 view (npy has no
bf16 dtype).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_path(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "__".join(out) or "root"


def _to_numpy(x) -> Tuple[np.ndarray, str]:
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16), "bfloat16"
    return x, str(x.dtype)


def _from_numpy(a: np.ndarray, dtype: str):
    if dtype == "bfloat16":
        return jnp.asarray(a.view(jnp.bfloat16))
    return jnp.asarray(a)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ----------------------------- save -----------------------------

    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot (device_get) then write; async unless blocking."""
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        snap = [(_leaf_path(p), _to_numpy(x)) for p, x in leaves]
        self.wait()
        if blocking:
            self._write(step, snap)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, snap), daemon=True)
            self._thread.start()

    def _write(self, step: int, snap):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for key, (arr, dtype) in snap:
            np.save(os.path.join(tmp, key + ".npy"), arr)
            manifest["leaves"][key] = {"dtype": dtype,
                                       "shape": list(arr.shape)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(name)
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ----------------------------- load -----------------------------

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            name = f.read().strip()
        if not os.path.exists(os.path.join(self.dir, name, "manifest.json")):
            return None
        return int(name.split("_")[1])

    def restore(self, step: int, like: Any,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of `like` (abstract or concrete).
        `shardings`: matching tree of NamedShardings for elastic
        re-placement onto the current mesh."""
        name = f"step_{step:08d}"
        base = os.path.join(self.dir, name)
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        sh_leaves = None
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
        out = []
        for i, (p, x) in enumerate(leaves):
            key = _leaf_path(p)
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint {name} is missing leaf {key}")
            arr = np.load(os.path.join(base, key + ".npy"))
            if list(arr.shape) != list(meta["shape"]):
                raise ValueError(f"corrupt leaf {key}: {arr.shape} vs "
                                 f"{meta['shape']}")
            val = _from_numpy(arr, meta["dtype"])
            want_shape = tuple(getattr(x, "shape", val.shape))
            if tuple(val.shape) != want_shape:
                raise ValueError(f"leaf {key}: checkpoint {val.shape} vs "
                                 f"model {want_shape} (arch mismatch)")
            if sh_leaves is not None:
                val = jax.device_put(val, sh_leaves[i])
            out.append(val)
        return jax.tree_util.tree_unflatten(treedef, [x for x in out])
